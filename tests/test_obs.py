"""Observability: trace recorder, metrics registry, injectable clocks,
straggler report (DESIGN.md §14).

The contract under test is "observe, never perturb": a disabled
recorder is a true no-op and a live one changes nothing about outputs;
the ring stays bounded under concurrent writers; the Chrome-trace
export is Perfetto's schema; Prometheus text and the JSON snapshot
round-trip; executor timer reads go through the injectable clock so
timing tests script time instead of sleeping; and trace_report's
attribution matches hand-computed goldens.
"""
import json
import threading

import numpy as np
import pytest

from repro.cad import CADConfig, CADSession
from repro.core.cost_model import CommModel
from repro.launch import trace_report
from repro.obs import (DEFAULT_BUCKETS, MONOTONIC, Clock, FakeClock,
                       MetricsRegistry, MonotonicClock, TraceRecorder,
                       disable_tracing, enable_tracing, get_recorder,
                       get_registry, server_track, set_recorder,
                       set_registry)
from repro.runtime import ElasticExecutor, FaultSchedule, ServerPool

BLK = 16


@pytest.fixture(autouse=True)
def _isolate_globals():
    """Every test runs against the default no-op recorder and a fresh
    registry; whatever it installs is torn down after."""
    prev_rec, prev_reg = get_recorder(), get_registry()
    set_recorder(None)
    set_registry(MetricsRegistry())
    yield
    set_recorder(prev_rec)
    set_registry(prev_reg)


def make_segs(d, nb, seed=0, max_doc_blocks=4):
    rng = np.random.default_rng(seed)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            dbl = int(rng.integers(1, min(max_doc_blocks, nb - t) + 1))
            segs[r, t * BLK:(t + dbl) * BLK] = sid
            sid += 1
            t += dbl
    return segs


def make_executor(d=4, nb=8, *, faults=None, **kw):
    cfg = CADConfig(n_servers=d, blk=BLK, nb=nb, cq=nb, ckv=2 * nb,
                    nkv=4 * nb)
    session = CADSession(cfg=cfg, comm=CommModel(2, 8, 2),
                        tolerance=0.05, jmax=nb, prefetch=0)
    session = session.with_pool(ServerPool(d))
    return ElasticExecutor(session, faults=faults, **kw)


def run_steps(ex, steps=3, d=4, nb=8, seed=0):
    outs, reports = [], []
    for step in range(steps):
        segs = make_segs(d, nb, seed=seed + step)
        pos = np.broadcast_to(np.arange(segs.shape[1]), segs.shape).copy()
        q, k, v, p = ex.synth_inputs(segs, pos, seed=seed + step)
        out, rep = ex.run_step(step, q, k, v, p, segs)
        outs.append(np.asarray(out))
        reports.append(rep)
    return outs, reports


# ===================================================================
# Clocks
# ===================================================================

def test_fake_clock_tick_and_advance():
    c = FakeClock(start=10.0, tick=0.5)
    assert c.monotonic() == 10.0
    assert c.monotonic() == 10.5         # auto-advanced by tick
    assert c.reads == 2
    assert c.advance(2.0) == 13.0
    assert c.monotonic() == 13.0
    with pytest.raises(ValueError):
        c.advance(-1.0)
    with pytest.raises(ValueError):
        FakeClock(tick=-0.1)


def test_fake_clock_is_deterministic_fixture():
    a = [FakeClock(tick=0.25).monotonic() for _ in range(3)]
    b = [FakeClock(tick=0.25).monotonic() for _ in range(3)]
    assert a == b


def test_clock_protocol():
    assert isinstance(MONOTONIC, Clock)
    assert isinstance(FakeClock(), Clock)
    t0 = MonotonicClock().monotonic()
    assert MonotonicClock().monotonic() >= t0


# ===================================================================
# TraceRecorder: no-op discipline, ring bounds, thread safety
# ===================================================================

def test_disabled_recorder_is_noop():
    rec = TraceRecorder(capacity=4, enabled=False)
    with rec.span("a", "t"):
        pass
    rec.add_span("b", "t", 0.0, 1.0)
    rec.instant("c", "t")
    assert len(rec) == 0 and rec.n_dropped == 0
    assert rec.events() == ()
    assert rec.to_chrome_trace()["traceEvents"] == []


def test_global_default_is_disabled_noop():
    rec = get_recorder()
    assert not rec.enabled
    rec.instant("x", "t")
    assert len(rec) == 0


def test_enable_disable_tracing_swaps_global():
    live = enable_tracing(capacity=16)
    assert get_recorder() is live and live.enabled
    live.instant("x", "t")
    assert len(get_recorder()) == 1
    disable_tracing()
    assert not get_recorder().enabled


def test_ring_bounds_and_drop_accounting():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.instant(f"e{i}", "t", ts=float(i))
    assert len(rec) == 8
    assert rec.n_dropped == 12
    # oldest retained first: events 12..19 survive
    assert [e.name for e in rec.events()] == [f"e{i}"
                                              for i in range(12, 20)]
    assert rec.to_chrome_trace()["otherData"]["dropped_events"] == 12
    rec.clear()
    assert len(rec) == 0 and rec.n_dropped == 0
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_recorder_thread_safety():
    rec = TraceRecorder(capacity=1000)
    n_threads, per = 8, 500

    def work(t):
        for i in range(per):
            if i % 2:
                rec.instant(f"i{t}.{i}", f"track/{t}", ts=float(i))
            else:
                rec.add_span(f"s{t}.{i}", f"track/{t}", float(i), 1.0)

    threads = [threading.Thread(target=work, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(rec) == 1000
    assert rec.n_dropped == n_threads * per - 1000
    evs = rec.events()
    assert len(evs) == 1000
    assert all(e is not None and e.name and e.track for e in evs)


def test_span_context_manager_measures_with_clock():
    clock = FakeClock(start=5.0, tick=0.5)
    rec = TraceRecorder(capacity=8, clock=clock)
    with rec.span("work", "main", step=3, args={"k": 1}):
        pass                             # enter + exit = two reads
    (ev,) = rec.events()
    assert ev.name == "work" and ev.track == "main"
    assert ev.ts == 5.0 and ev.dur == pytest.approx(0.5)
    assert ev.step == 3 and ev.args == {"k": 1}


# ===================================================================
# Chrome-trace export schema
# ===================================================================

def test_chrome_trace_schema(tmp_path):
    rec = TraceRecorder(capacity=32)
    rec.add_span("serve", server_track(0), 1.0, 0.25, step=0,
                 args={"predicted": np.float64(0.3)})
    rec.instant("kill", server_track(1), ts=1.5, step=0)
    trace = rec.to_chrome_trace()
    evs = trace["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    names = {e["tid"]: e["args"]["name"] for e in meta}
    assert sorted(names.values()) == ["server/0", "server/1"]
    span = next(e for e in evs if e["ph"] == "X")
    assert span["ts"] == pytest.approx(1.0e6)      # microseconds
    assert span["dur"] == pytest.approx(0.25e6)
    assert span["args"]["step"] == 0
    assert isinstance(span["args"]["predicted"], float)  # np -> float
    assert names[span["tid"]] == "server/0"
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t" and inst["ts"] == pytest.approx(1.5e6)
    # save() writes the same loadable JSON
    p = tmp_path / "t.trace.json"
    rec.save(str(p))
    with open(p) as f:
        assert json.load(f)["traceEvents"] == json.loads(
            json.dumps(evs))


# ===================================================================
# MetricsRegistry
# ===================================================================

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("steps_total", "steps", labels=())
    c.inc()
    c.inc(2.0)
    assert c.value() == 3.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("epoch", labels=("server",))
    g.set(4, server=1)
    assert g.value(server=1) == 4.0
    assert g.value(server=2) is None     # never-set series
    with pytest.raises(ValueError):
        g.set(1.0, wrong="x")            # undeclared label
    with pytest.raises(TypeError):
        g.inc()                          # kind mismatch


def test_family_registration_idempotent_and_conflicting():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", labels=("k",))
    b = reg.counter("x_total", labels=("k",))
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("x_total", labels=("k",))
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("other",))


def test_histogram_buckets_and_text_exposition():
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 2.0))
    for v in (0.5, 1.0, 1.5, 3.0):
        h.observe(v)
    txt = reg.to_text()
    # le is cumulative: <=1.0 catches 0.5 and the boundary 1.0
    assert 'lat_bucket{le="1"} 2' in txt
    assert 'lat_bucket{le="2"} 3' in txt
    assert 'lat_bucket{le="+Inf"} 4' in txt
    assert "lat_sum 6" in txt and "lat_count 4" in txt
    assert "# TYPE lat histogram" in txt
    assert "# HELP lat latency" in txt
    assert h.value() == pytest.approx(6.0)   # histogram value = sum


def test_text_exposition_labeled_series():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests", labels=("code", "path"))
    c.inc(3, code=200, path="/x")
    c.inc(1, code=500, path="/x")
    txt = reg.to_text()
    assert '# TYPE req_total counter' in txt
    assert 'req_total{code="200",path="/x"} 3' in txt
    assert 'req_total{code="500",path="/x"} 1' in txt


def test_json_round_trip_exact():
    reg = MetricsRegistry()
    reg.counter("a_total", "A").inc(5)
    reg.gauge("b", "B", labels=("s",)).set(1.5, s=0)
    h = reg.histogram("c_seconds", "C", buckets=DEFAULT_BUCKETS)
    h.observe(0.01)
    h.observe(2.0)
    d = reg.to_dict()
    json.dumps(d)                        # JSON-able
    reg2 = MetricsRegistry.from_dict(d)
    assert reg2.to_dict() == d
    assert reg2.to_text() == reg.to_text()


def test_set_registry_none_installs_fresh():
    get_registry().counter("junk_total").inc()
    fresh = set_registry(None)
    assert fresh is get_registry()
    assert fresh.counter("junk_total").value() is None


# ===================================================================
# Executor instrumentation: no-perturbation, trace content, residuals
# ===================================================================

def test_traced_run_bit_identical_to_untraced():
    faults = FaultSchedule.parse("kill:1@1")
    base, _ = run_steps(make_executor(faults=faults), steps=3)
    rec = TraceRecorder(capacity=4096)
    traced, _ = run_steps(
        make_executor(faults=faults, recorder=rec,
                      metrics=MetricsRegistry()), steps=3)
    assert len(rec) > 0                  # it really did record
    for a, b in zip(base, traced):
        assert a.tobytes() == b.tobytes()


def test_executor_trace_narrates_fault_and_recovery():
    rec = TraceRecorder(capacity=4096)
    mx = MetricsRegistry()
    ex = make_executor(faults=FaultSchedule.parse("kill:1@1"),
                       recorder=rec, metrics=mx)
    _, reports = run_steps(ex, steps=3)
    evs = rec.events()
    kills = [e for e in evs if e.name == "kill"]
    assert len(kills) == 1
    assert kills[0].track == server_track(1) and kills[0].step == 1
    recovers = [e for e in evs if e.name == "recover" and e.step == 1]
    assert recovers and all(e.dur > 0 for e in recovers)
    assert all(e.track != server_track(1) for e in recovers)
    # cumulative step timeline: step n starts where step n-1 ended
    steps = sorted((e for e in evs
                    if e.name == "step" and e.track == "step"),
                   key=lambda e: e.step)
    assert len(steps) == 3
    for prev, nxt in zip(steps, steps[1:]):
        assert nxt.ts == pytest.approx(prev.ts + prev.dur)
    assert steps[1].args["failed"] == [1]
    # metrics tell the same story
    assert mx.counter("cad_steps_total").value() == 3.0
    assert mx.counter("cad_failures_total").value() == 1.0
    assert mx.counter("cad_recovered_blocks_total").value() \
        == float(sum(r.recovered_blocks for r in reports))
    assert mx.gauge("cad_pool_epoch").value() == reports[-1].epoch


def test_rigged_calibrator_residual_gauge():
    # model timer: measured = predicted * slow, so a 2x-slowed server
    # shows residual (2p - p)/2p = 0.5 and healthy servers exactly 0
    mx = MetricsRegistry()
    ex = make_executor(faults=FaultSchedule.parse("slow:1x2@0-9"),
                       metrics=mx)
    run_steps(ex, steps=2)
    resid = mx.gauge("cad_calib_residual", labels=("server",))
    assert resid.value(server=1) == pytest.approx(0.5)
    assert resid.value(server=0) == pytest.approx(0.0)
    assert resid.value(server=3) == pytest.approx(0.0)


def test_wall_timer_reads_injectable_clock():
    # satellite (a): the executor's wall timer goes through the clock;
    # a FakeClock turns wall timing into a deterministic fixture
    clock = FakeClock(tick=0.25)
    ex = make_executor(timer="wall", clock=clock)
    assert ex.clock is clock
    _, (rep,) = run_steps(ex, steps=1)
    assert clock.reads > 0
    for s, sec in rep.server_seconds.items():
        assert sec == pytest.approx(0.25)    # one tick per paired read


def test_model_timer_never_reads_wall_clock():
    clock = FakeClock(tick=1.0)
    ex = make_executor(timer="model", clock=clock)
    _, (rep,) = run_steps(ex, steps=1)
    assert clock.reads == 0
    assert all(sec > 0 for sec in rep.server_seconds.values())


# ===================================================================
# trace_report: straggler attribution goldens
# ===================================================================

def golden_trace():
    rec = TraceRecorder(capacity=64)
    rec.add_span("serve", server_track(0), 0.0, 2.0, step=0,
                 args={"predicted": 1.9})
    rec.add_span("serve", server_track(2), 0.0, 1.0, step=0,
                 args={"predicted": 1.1})
    rec.add_span("recover", server_track(0), 2.0, 0.5, step=0)
    rec.instant("kill", server_track(1), ts=0.0, step=0)
    rec.add_span("serve", server_track(1), 3.0, 4.0, step=1,
                 args={"predicted": 4.2})
    rec.add_span("serve.backfill", server_track(1), 7.0, 1.0, step=1)
    return rec.to_chrome_trace()


def test_trace_report_golden_attribution():
    steps = trace_report.load_steps(golden_trace())
    assert sorted(steps) == [0, 1]
    a0 = trace_report.attribute_step(steps[0])
    assert a0["server"] == 0
    assert a0["max_seconds"] == pytest.approx(2.5)   # serve + recover
    assert a0["mean_seconds"] == pytest.approx((2.5 + 1.0) / 2)
    assert a0["predicted_seconds"] == pytest.approx(1.9)
    assert a0["recovery_share"] == pytest.approx(0.5 / 2.5)
    assert a0["events"] == ["kill"]
    a1 = trace_report.attribute_step(steps[1])
    assert a1["server"] == 1
    assert a1["max_seconds"] == pytest.approx(5.0)   # serve + backfill
    assert a1["recovery_share"] == 0.0
    assert a1["events"] == []


def test_trace_report_tie_breaks_lowest_slot():
    servers = {3: {"serve": 1.0, "recover": 0.0, "predicted": 0.0,
                   "events": []},
               1: {"serve": 1.0, "recover": 0.0, "predicted": 0.0,
                   "events": []}}
    assert trace_report.attribute_step(servers)["server"] == 1


def test_trace_report_lines_and_empty(capsys, tmp_path):
    lines = trace_report.report_lines(golden_trace())
    assert len(lines) == 3               # header + 2 steps
    assert "kill" in lines[1] and lines[1].split()[0] == "0"
    assert trace_report.report_lines({"traceEvents": []})[-1] \
        == "(no per-step server events in trace)"
    # CLI --json end-to-end over a saved file
    p = tmp_path / "g.json"
    with open(p, "w") as f:
        json.dump(golden_trace(), f)
    trace_report.main([str(p), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert out["0"]["server"] == 0
    assert out["1"]["max_seconds"] == pytest.approx(5.0)


def test_executor_trace_feeds_trace_report():
    rec = TraceRecorder(capacity=4096)
    ex = make_executor(faults=FaultSchedule.parse("kill:1@1"),
                       recorder=rec, metrics=MetricsRegistry())
    _, reports = run_steps(ex, steps=2)
    steps = trace_report.load_steps(rec.to_chrome_trace())
    a = trace_report.attribute_step(steps[1])
    totals = {s: reports[1].server_seconds.get(s, 0.0)
              + reports[1].recovery_seconds.get(s, 0.0)
              for s in reports[1].server_seconds}
    want = max(sorted(totals), key=lambda s: totals[s])
    assert a["server"] == want
    assert a["max_seconds"] == pytest.approx(totals[want])
    assert "kill" in a["events"]
