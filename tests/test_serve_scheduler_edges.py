"""Serve-scheduler edge cases (DESIGN.md §8) found untested while
reading ``serve/scheduler.py``: admission when the token budget is
exactly consumed, zero-length prompt handling, and preemption around
the sole running request (the forward-progress guarantee)."""
import numpy as np
import pytest

from repro.core.cost_model import CostModel
from repro.serve.scheduler import (DECODE, PREFILL, WAITING,
                                   ContinuousScheduler, Request,
                                   SchedulerConfig)


def sched_of(n_slots=2, max_seq=256, token_budget=None, **kw):
    return ContinuousScheduler(SchedulerConfig(
        n_slots=n_slots, max_seq=max_seq, token_budget=token_budget,
        **kw))


def req(rid, prompt_len, max_new=4):
    return Request(rid=rid, prompt=np.arange(1, prompt_len + 1,
                                             dtype=np.int32),
                   max_new_tokens=max_new)


def drive(sched, max_steps=500):
    """Run the scheduler state machine to completion with fake model
    outputs (token 7), evicting between steps like the engine does."""
    for _ in range(max_steps):
        if not sched.has_work():
            return
        sched.admit()
        sched.evict_for_budget()
        chunk = sched.next_prefill_chunk()
        if chunk is not None:
            sched.commit_prefill(chunk, {slot: 7
                                         for slot, _row in
                                         chunk.last_rows})
            continue
        if sched.decode_batch() is not None:
            sched.commit_decode(np.full(sched.cfg.n_slots, 7, np.int32))
    raise AssertionError("scheduler did not finish (livelock?)")


# ----------------------------------------------------- zero-length prompts
def test_zero_length_prompt_rejected_on_submit():
    s = sched_of()
    with pytest.raises(ValueError, match="empty prompt"):
        s.submit(req(0, 0))
    assert not s.has_work()                  # nothing half-enqueued


def test_oversized_prompt_rejected_on_submit():
    s = sched_of(max_seq=64)
    with pytest.raises(ValueError, match="exceeds"):
        s.submit(req(0, 61, max_new=4))
    s.submit(req(1, 60, max_new=4))          # exactly max_seq fits


# ------------------------------------------------------- exact token budget
def test_admission_at_exactly_consumed_budget():
    """prompt + one decode step of growth == budget admits (<=, not <);
    one token less blocks."""
    s = sched_of(n_slots=2, token_budget=9)
    s.submit(req(0, 8, max_new=4))           # needs 8 + 1 == 9
    assert [r.rid for r in s.admit()] == [0]
    assert s.active[0].state == PREFILL

    tight = sched_of(n_slots=2, token_budget=8)
    tight.submit(req(1, 8, max_new=4))       # needs 9 > 8: never fits
    with pytest.raises(RuntimeError, match="can never be admitted"):
        tight.admit()


def test_prefill_only_request_needs_no_growth_token():
    """max_new_tokens == 0 skips the +1 growth reservation, so a budget
    of exactly prompt_len admits."""
    s = sched_of(n_slots=1, token_budget=8)
    s.submit(req(0, 8, max_new=0))
    assert [r.rid for r in s.admit()] == [0]
    drive(s)
    assert s.done[0].out_tokens == []        # finished with no output


def test_exact_budget_co_admission_and_head_of_line():
    """Two requests fitting the budget exactly co-admit; one token less
    and the second blocks (head-of-line, deterministic order).  The
    boundary: the admitted request commits its 8 prompt tokens, the
    candidate needs prompt + 1 growth -> 8 + 9 == 17 exactly."""
    s = sched_of(n_slots=2, token_budget=17)
    s.submit(req(0, 8, max_new=4))
    s.submit(req(1, 8, max_new=4))
    assert [r.rid for r in s.admit()] == [0, 1]

    t = sched_of(n_slots=2, token_budget=16)
    t.submit(req(0, 8, max_new=4))
    t.submit(req(1, 8, max_new=4))
    assert [r.rid for r in t.admit()] == [0]
    assert [r.rid for r in t.waiting] == [1]
    # the blocked request is admitted later, once slot 0 drains
    drive(t)
    assert sorted(r.rid for r in t.done) == [0, 1]
    assert [e for e in t.trace if e[0] == "evict"] == []


def test_cost_admission_at_exact_budget():
    """Cheapest-first admission, boundary-exact.  Prompts are sized so
    their predicted per-step cost actually differs (the analytic grid
    clips kv below its first cell, which would tie tiny prompts): rid 1
    commits 256 prefill tokens, rid 0 then needs 512 + 1 growth -> 769
    total; budget 769 admits both, 768 stops after the cheap one."""
    cm = CostModel.analytic(2, 16)
    s = sched_of(n_slots=2, max_seq=1024, token_budget=769,
                 admission="cost", cost_model=cm)
    s.submit(req(0, 512, max_new=4))         # dearer (longer total)
    s.submit(req(1, 256, max_new=4))         # cheapest: admitted first
    assert [r.rid for r in s.admit()] == [1, 0]

    t = sched_of(n_slots=2, max_seq=1024, token_budget=768,
                 admission="cost", cost_model=cm)
    t.submit(req(0, 512, max_new=4))
    t.submit(req(1, 256, max_new=4))
    assert [r.rid for r in t.admit()] == [1]
    assert [r.rid for r in t.waiting] == [0]


# -------------------------------------------------------------- preemption
def test_sole_running_request_never_preempted():
    """The oldest active request runs to completion even when it alone
    exceeds the budget — the budget goes soft for the last request
    (forward-progress guarantee)."""
    s = sched_of(n_slots=1, token_budget=10)
    s.submit(req(0, 8, max_new=16))          # will grow to 24 > 10
    s.admit()
    # prefill fully, then decode past the budget
    drive(s)
    assert s.done and s.done[0].rid == 0
    assert len(s.done[0].out_tokens) == 16   # ran to completion
    assert s.done[0].n_evictions == 0
    assert [e for e in s.trace if e[0] == "evict"] == []


def test_preemption_evicts_youngest_not_sole():
    """With two active requests busting the budget, only the younger is
    evicted (LIFO), requeued at the *front*, progress discarded."""
    s = sched_of(n_slots=2, token_budget=20)
    s.submit(req(0, 8, max_new=16))
    s.submit(req(1, 8, max_new=16))
    s.admit()
    # decode both until the budget bursts
    for _ in range(40):
        chunk = s.next_prefill_chunk()
        if chunk is not None:
            s.commit_prefill(chunk, {slot: 7 for slot, _ in
                                     chunk.last_rows})
            continue
        if s._live_tokens() > s.cfg.token_budget:
            break
        if s.decode_batch() is None:
            break
        s.commit_decode(np.full(2, 7, np.int32))
    evicted = s.evict_for_budget()
    assert [r.rid for r in evicted] == [1]   # youngest only
    assert s.trace[-1] == ("evict", 1)
    r1 = evicted[0]
    assert r1.state == WAITING and r1.slot == -1
    assert r1.n_prefilled == 0 and r1.out_tokens == []
    assert r1.n_evictions == 1
    assert s.waiting[0].rid == 1             # requeued at the front
    assert s.active and next(iter(s.active.values())).rid == 0
    # and the whole workload still completes (recompute preemption)
    drive(s)
    assert sorted(r.rid for r in s.done) == [0, 1]
    assert len(s.done[-1].out_tokens) == 16


def test_preemption_is_lifo_over_admit_order():
    s = sched_of(n_slots=3, token_budget=60)
    for i in range(3):
        s.submit(req(i, 16, max_new=8))
    s.admit()
    order = [r.admit_seq for r in s.active.values()]
    assert sorted(order) == order            # admitted in arrival order
    # force a deep overshoot: shrink the budget under the committed sum
    s.cfg.token_budget = 18
    evicted = s.evict_for_budget()
    assert [r.rid for r in evicted] == [2, 1]     # LIFO, oldest kept
    assert [r.rid for r in s.waiting] == [1, 2]   # fronts stack in order
    assert [r.rid for r in s.active.values()] == [0]


def test_empty_scheduler_steps_return_none():
    s = sched_of()
    assert s.next_prefill_chunk() is None
    assert s.next_prefill_chunk(fused=False) is None
    assert s.decode_batch() is None
    assert s.evict_for_budget() == []
    assert not s.has_prefill()


def test_decode_state_after_exact_prefill_chunk_boundary():
    """A prompt that exactly fills its chunk blocks transitions to
    DECODE in the same chunk (last_rows recorded on the boundary)."""
    s = sched_of(n_slots=1, max_seq=1024, chunk_tokens=128,
                 token_budget=1024)
    s.submit(req(0, 128, max_new=2))         # prompt == chunk exactly
    s.admit()
    chunk = s.next_prefill_chunk()
    assert chunk is not None
    assert chunk.last_rows == [(0, 127)]
    s.commit_prefill(chunk, {0: 7})
    assert s.active[0].state == DECODE
    assert int(s.kv_len[0]) == 128
