"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests
against the pure-jnp oracles (interpret mode on CPU)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; property tests only
from hypothesis import given, settings, strategies as st

from repro.core.attention import ref_attention, xla_flash_attention
from repro.kernels.packed_flash import kernel as K
from repro.kernels.packed_flash import ops as O
from repro.kernels.packed_flash import ref as R


def make_packed(key, B, S, Hq, Hkv, dh, dtype, n_docs=3):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, Hq, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh)).astype(dtype)
    # random doc boundaries per row
    rng = np.random.default_rng(int(ks[3][0]))
    seg = np.zeros((B, S), np.int32)
    pos = np.zeros((B, S), np.int32)
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(1, S), size=n_docs - 1,
                                  replace=False))
        bounds = np.concatenate([[0], cuts, [S]])
        for d in range(n_docs):
            lo, hi = bounds[d], bounds[d + 1]
            seg[b, lo:hi] = d + 1
            pos[b, lo:hi] = np.arange(hi - lo)
    return q, k, v, jnp.asarray(seg), jnp.asarray(pos)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Hq,Hkv,dh,blk", [
    (256, 4, 2, 64, 128),
    (256, 2, 2, 128, 128),
    (512, 8, 1, 64, 128),   # MQA
    (384, 6, 2, 128, 128),  # non-power-of-two seq (3 blocks)
    (256, 4, 4, 256, 64),   # gemma-style head_dim, small block
])
def test_flash_fwd_sweep(dtype, S, Hq, Hkv, dh, blk):
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(0), 2, S, Hq, Hkv,
                                    dh, dtype)
    out = K.flash_fwd(q, k, v, seg, pos, seg, pos, blk_q=blk, blk_k=blk)
    exp = ref_attention(q, k, v, seg, pos, seg, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("causal,window,softcap", [
    (True, 0, 0.0), (True, 64, 0.0), (True, 0, 50.0), (False, 0, 0.0),
    (True, 128, 30.0)])
def test_flash_fwd_masks(causal, window, softcap):
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(1), 2, 256, 4, 2, 64,
                                    jnp.float32)
    out = K.flash_fwd(q, k, v, seg, pos, seg, pos, causal=causal,
                      window=window, softcap=softcap)
    exp = ref_attention(q, k, v, seg, pos, seg, pos, causal=causal,
                        window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_xla_flash_matches_ref():
    """The dry-run path (xla impl) agrees with the oracle too."""
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(2), 2, 320, 4, 2, 64,
                                    jnp.float32)
    for window, softcap in [(0, 0.0), (96, 50.0)]:
        out = xla_flash_attention(q, k, v, seg, pos, seg, pos, window=window,
                                  softcap=softcap, q_block=128, kv_block=64)
        exp = ref_attention(q, k, v, seg, pos, seg, pos, window=window,
                            softcap=softcap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5)


def test_flash_grads_match_ref():
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(3), 1, 256, 4, 2, 64,
                                    jnp.float32)

    def loss_k(q_, k_, v_):
        return jnp.sum(O.packed_flash_attention(q_, k_, v_, seg, pos, seg,
                                                pos) ** 2)

    def loss_r(q_, k_, v_):
        return jnp.sum(ref_attention(q_, k_, v_, seg, pos, seg, pos) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


# ------------------------------------------------------------- CA server
def make_server_batch(key, T, blk, Hq, Hkv, dh, N, dtype=jnp.float32,
                      seed=0):
    ks = jax.random.split(key, 4)
    rng = np.random.default_rng(seed)
    q = jax.random.normal(ks[0], (T, blk, Hq, dh)).astype(dtype)
    kb = jax.random.normal(ks[1], (N, blk, Hkv, dh)).astype(dtype)
    vb = jax.random.normal(ks[2], (N, blk, Hkv, dh)).astype(dtype)
    kv_start = np.zeros(T, np.int32)
    kv_len = np.zeros(T, np.int32)
    q_pos = np.zeros((T, blk), np.int32)
    kv_pos = np.zeros((N, blk), np.int32)
    for n in range(N):
        kv_pos[n] = np.arange(blk)  # per-block positions filled per task
    for t in range(T):
        ln = int(rng.integers(1, min(N, 6) + 1))
        st = int(rng.integers(0, N - ln + 1))
        kv_start[t], kv_len[t] = st, ln
        # q block = last block of prefix; positions continue the prefix
        q_pos[t] = np.arange((ln - 1) * blk, ln * blk)
        for jj in range(ln):
            kv_pos[st + jj] = np.arange(jj * blk, (jj + 1) * blk)
    if T > 1:  # make last task padding
        kv_len[-1] = 0
    return (q, kb, vb, jnp.asarray(kv_start), jnp.asarray(kv_len),
            jnp.asarray(q_pos), jnp.asarray(kv_pos))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,blk,Hq,Hkv,dh,N", [
    (4, 128, 4, 2, 64, 8),
    (6, 128, 2, 1, 128, 6),
    (3, 64, 8, 8, 64, 5),
])
def test_ca_server_sweep(dtype, T, blk, Hq, Hkv, dh, N):
    args = make_server_batch(jax.random.PRNGKey(4), T, blk, Hq, Hkv, dh, N,
                             dtype)
    out = K.ca_server_fwd(*args)
    exp = R.ref_ca_server_attention(*args)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_ca_server_grads():
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(5), 4, 64, 4, 2, 64, 6)

    def loss_k(q_, k_, v_):
        return jnp.sum(O.ca_server_attention(q_, k_, v_, st, ln, qp,
                                             kp) ** 2)

    def loss_r(q_, k_, v_):
        return jnp.sum(R.ref_ca_server_attention(q_, k_, v_, st, ln, qp,
                                                 kp) ** 2)

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, kb, vb)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, kb, vb)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)


# -------------------------------------------------------------- property
@settings(max_examples=15, deadline=None)
@given(
    s_blocks=st.integers(1, 4),
    hq=st.sampled_from([1, 2, 4]),
    rep=st.sampled_from([1, 2]),
    dh=st.sampled_from([64, 128]),
    n_docs=st.integers(1, 4),
    seed=st.integers(0, 2 ** 16),
)
def test_flash_property(s_blocks, hq, rep, dh, n_docs, seed):
    """Kernel == oracle for random shapes, doc layouts, GQA factors."""
    if hq % rep:
        rep = 1
    S = 128 * s_blocks
    n_docs = min(n_docs, S - 1)
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(seed), 1, S, hq,
                                    hq // rep, dh, jnp.float32,
                                    n_docs=max(n_docs, 1))
    out = K.flash_fwd(q, k, v, seg, pos, seg, pos)
    exp = ref_attention(q, k, v, seg, pos, seg, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(2, 6),
    n=st.integers(2, 8),
    seed=st.integers(0, 2 ** 16),
)
def test_ca_server_property(t, n, seed):
    """Fused CA-task batches match the oracle for arbitrary task layouts —
    the paper's composability claim (§3.3) as an executable property."""
    args = make_server_batch(jax.random.PRNGKey(seed), t, 64, 4, 2, 64, n,
                             seed=seed)
    out = K.ca_server_fwd(*args)
    exp = R.ref_ca_server_attention(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)
