"""RG-LRU Pallas kernel: shape/dtype sweeps + property tests vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; property tests only
from hypothesis import given, settings, strategies as st

from repro.kernels.rglru import kernel as K
from repro.kernels.rglru import ops as O
from repro.kernels.rglru import ref as R


def make(key, B, S, W, dtype=jnp.float32, amax=0.99):
    ka, kb = jax.random.split(key)
    a = (jax.nn.sigmoid(jax.random.normal(ka, (B, S, W))) * amax) \
        .astype(dtype)
    b = jax.random.normal(kb, (B, S, W)).astype(dtype)
    return a, b


def test_oracle_self_consistent():
    a, b = make(jax.random.PRNGKey(0), 2, 256, 128)
    np.testing.assert_allclose(
        np.asarray(R.ref_lru_scan(a, b)),
        np.asarray(R.ref_lru_scan_sequential(a, b)), atol=1e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 5e-2)])
@pytest.mark.parametrize("B,S,W,ts,tw", [
    (2, 512, 256, 128, 128),
    (1, 256, 128, 256, 128),
    (3, 384, 384, 128, 128),
    (2, 512, 256, 64, 256),
])
def test_lru_kernel_sweep(dtype, tol, B, S, W, ts, tw):
    a, b = make(jax.random.PRNGKey(1), B, S, W, dtype)
    h = K.lru_scan(a, b, tile_s=ts, tile_w=tw)
    exp = R.ref_lru_scan(a, b)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(exp, np.float32),
                               atol=tol, rtol=tol)


def test_lru_resets_zero_decay():
    """a=0 rows isolate segments exactly (how doc resets are encoded)."""
    a, b = make(jax.random.PRNGKey(2), 1, 256, 128)
    a = a.at[:, 128].set(0.0)
    h = K.lru_scan(a, b, tile_s=64)
    # second segment must equal an independent scan of its own slice
    h2 = K.lru_scan(a[:, 128:], b[:, 128:], tile_s=64)
    np.testing.assert_allclose(np.asarray(h[:, 128:]), np.asarray(h2),
                               atol=1e-4)


def test_lru_grads():
    a, b = make(jax.random.PRNGKey(3), 2, 256, 128)
    f = lambda a_, b_: jnp.sum(O.lru_scan(a_, b_) ** 2)
    fr = lambda a_, b_: jnp.sum(R.ref_lru_scan(a_, b_) ** 2)
    g = jax.grad(f, argnums=(0, 1))(a, b)
    gr = jax.grad(fr, argnums=(0, 1))(a, b)
    for x, y in zip(g, gr):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=2e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(s_tiles=st.integers(1, 4), w_tiles=st.integers(1, 2),
       seed=st.integers(0, 2 ** 16))
def test_lru_property(s_tiles, w_tiles, seed):
    S, W = 128 * s_tiles, 128 * w_tiles
    a, b = make(jax.random.PRNGKey(seed), 1, S, W)
    h = K.lru_scan(a, b, tile_s=128)
    np.testing.assert_allclose(np.asarray(h),
                               np.asarray(R.ref_lru_scan(a, b)), atol=1e-4)
