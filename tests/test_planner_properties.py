"""Property-based planner/scheduler invariants.

For random document-length mixes, pool sizes, speed factors, cost
models, and tolerances, every registered policy must yield plans that

  * cover each live q-block exactly once (and padding never),
  * encode every task's kv context as the document's exact prefix,
  * respect the static send/buffer capacities,
  * account loads consistently (work is conserved under speed scaling),
  * plan deterministically (same inputs -> bit-identical arrays),
  * never balance *worse* than identity,
  * under elastic membership subsets (``exclude``), never place a task
    on an excluded server while still covering every live block, and
  * fail infeasible builds with ``PlanCapacityError`` — never a bare
    assert or a silent overflow.

The suite runs under hypothesis when it is installed (CI installs the
``dev`` extra, so there it must run, not skip); without hypothesis the
same generators and checks run as a seeded random sweep, so the
invariants stay enforced in minimal environments too.  Both paths share
one scenario generator through the tiny ``Sampler`` interface below.
"""

import numpy as np
import pytest

from repro.cad import (CADConfig, PlanCapacityError, PlanMemoryError,
                       available_policies, get_planner)
from repro.core.cost_model import CommModel, CostModel, MemoryModel
from repro.core.mask import MaskSpec
from repro.core.plan import identity_assignment, plan_from_assignment
from repro.core.scheduler import (assignment_resident_bytes, block_costs,
                                  layout_from_segments)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False

BLK = 16
N_EXAMPLES = 40
POLICIES = sorted(available_policies())


# ------------------------------------------------------------ generators
class RngSampler:
    """numpy-backed stand-in for hypothesis draws (the no-hypothesis
    fallback sweep)."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def int_(self, lo: int, hi: int) -> int:
        return int(self._rng.integers(lo, hi + 1))

    def choice(self, seq):
        return seq[self.int_(0, len(seq) - 1)]

    def bool_(self, p: float = 0.5) -> bool:
        return bool(self._rng.random() < p)


class HypSampler:
    """The same interface backed by a hypothesis ``data`` draw, so
    shrinking works on every decision the generator makes."""

    def __init__(self, draw):
        self._draw = draw

    def int_(self, lo: int, hi: int) -> int:
        return self._draw(st.integers(lo, hi))

    def choice(self, seq):
        return self._draw(st.sampled_from(list(seq)))

    def bool_(self, p: float = 0.5) -> bool:
        # p only shapes the fallback sweep; hypothesis explores both
        return self._draw(st.booleans())


def property_case(fn):
    """Run ``fn(sampler)`` under hypothesis when available, else as a
    seeded random sweep over the same generator.  (No functools.wraps:
    pytest must see the *wrapper's* signature, not ``fn``'s.)"""
    if HAVE_HYPOTHESIS:
        def hyp_wrapper(data):
            fn(HypSampler(data.draw))
        hyp_wrapper.__name__ = fn.__name__
        hyp_wrapper.__doc__ = fn.__doc__
        return settings(max_examples=N_EXAMPLES, deadline=None)(
            given(st.data())(hyp_wrapper))

    def sweep_wrapper(seed):
        fn(RngSampler(np.random.default_rng(seed)))
    sweep_wrapper.__name__ = fn.__name__
    sweep_wrapper.__doc__ = fn.__doc__
    return pytest.mark.parametrize("seed", range(N_EXAMPLES))(
        sweep_wrapper)


def gen_scenario(s):
    """Random pool + packed-batch layout honoring the pipeline contract:
    blocks are document-pure; a doc's last block may be partially filled
    (trailing zeros); whole padding blocks may separate docs."""
    d = s.int_(1, 4)
    nb = s.int_(2, 8)
    segs = np.zeros((d, nb * BLK), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < nb:
            if s.bool_(0.15):                 # padding block
                t += 1
                continue
            dbl = s.int_(1, min(4, nb - t))
            tokens = dbl * BLK
            if s.bool_(0.3):                  # partial last block
                tokens -= s.int_(0, BLK - 1)
            segs[r, t * BLK:t * BLK + tokens] = sid
            sid += 1
            t += dbl
    cfg = CADConfig(n_servers=d, blk=BLK, nb=nb, cq=nb, ckv=2 * nb,
                    nkv=4 * nb,
                    server_speeds=tuple(s.choice([0.25, 0.5, 1.0])
                                        for _ in range(d))
                    if s.bool_(0.5) else None)
    cost_model = CostModel.analytic(4, 32).scaled(s.choice([1.0, 2.5])) \
        if s.bool_(0.4) else None
    tolerance = s.choice([0.02, 0.1, 0.3])
    return cfg, segs, cost_model, tolerance


# ---------------------------------------------------------------- checks
def plan_served_blocks(cfg, plan):
    """(global block -> server) mapping reconstructed from the dispatch
    arrays; blocks appearing more than once are reported as duplicates."""
    d, nb = cfg.n_servers, cfg.nb
    served, dupes = {}, []
    q_home = np.asarray(plan["q_home_idx"])
    q_send = np.asarray(plan["q_send_idx"])
    for r in range(d):
        for i in range(nb):
            if q_home[r, i] >= 0:
                g = r * nb + int(q_home[r, i])
                if g in served:
                    dupes.append(g)
                else:
                    served[g] = r
    for src in range(d):
        for dst in range(d):
            for c in range(cfg.cq):
                idx = int(q_send[src, dst, c])
                if idx >= 0:
                    g = src * nb + idx
                    if g in served:
                        dupes.append(g)
                    else:
                        served[g] = dst
    return served, dupes


def resolve_kv_slot(cfg, plan, server, buf_pos):
    """kv buffer position -> the global kv block it holds."""
    nb, ckv = cfg.nb, cfg.ckv
    slot = int(np.asarray(plan["kv_gather"])[server, buf_pos])
    assert slot >= 0, "task kv range points at an empty buffer slot"
    if slot < nb:
        return server * nb + slot
    src, c = divmod(slot - nb, ckv)
    idx = int(np.asarray(plan["kv_send_idx"])[src, server, c])
    assert idx >= 0, "kv gather references an unused recv slot"
    return src * nb + idx


def task_q_block(cfg, plan, server, slot):
    """task slot -> the global q block it serves (or None if empty)."""
    nb, cq = cfg.nb, cfg.cq
    if slot < nb:
        idx = int(np.asarray(plan["q_home_idx"])[server, slot])
        return server * nb + idx if idx >= 0 else None
    src, c = divmod(slot - nb, cq)
    idx = int(np.asarray(plan["q_send_idx"])[src, server, c])
    return src * nb + idx if idx >= 0 else None


def run_policy(policy, cfg, segs, cost_model, tolerance):
    return get_planner(policy)(cfg, segs, comm=None, tolerance=tolerance,
                               cost_model=cost_model)


# ------------------------------------------------------------ properties
@property_case
def test_coverage_exactly_once(s):
    """Every live q-block is served exactly once; padding never."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    res = run_policy(policy, cfg, segs, cm, tol)
    _docs, doc_of, _bi = layout_from_segments(segs, cfg.blk,
                                              cfg.n_servers)
    served, dupes = plan_served_blocks(cfg, res.plan)
    assert not dupes, f"{policy}: blocks served twice: {dupes}"
    for g in range(cfg.n_servers * cfg.nb):
        if doc_of[g] >= 0:
            assert g in served, f"{policy}: live block {g} never served"
            assert served[g] == int(res.assign[g]), \
                f"{policy}: plan serves {g} on {served[g]}, " \
                f"assign says {res.assign[g]}"
        else:
            assert g not in served, f"{policy}: padding block {g} served"


@property_case
def test_task_kv_is_doc_prefix(s):
    """Each task's kv buffer range resolves to its document's exact
    prefix, in order — the invariant the server kernels assume."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    res = run_policy(policy, cfg, segs, cm, tol)
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                               cfg.n_servers)
    kv_start = np.asarray(res.plan["task_kv_start"])
    kv_len = np.asarray(res.plan["task_kv_len"])
    for srv in range(cfg.n_servers):
        for slot in range(cfg.n_tasks):
            ln = int(kv_len[srv, slot])
            if ln == 0:
                continue
            g = task_q_block(cfg, res.plan, srv, slot)
            assert g is not None, "live task slot without a q block"
            dc = int(doc_of[g])
            assert ln == int(bi_of[g]) + 1, \
                f"task context is not the causal prefix ({ln} vs " \
                f"{bi_of[g] + 1})"
            g0 = docs[dc].g0
            start = int(kv_start[srv, slot])
            for j in range(ln):
                assert resolve_kv_slot(cfg, res.plan, srv, start + j) \
                    == g0 + j, "kv prefix out of order"


@property_case
def test_capacities_respected(s):
    """Send-slot and buffer usage never exceeds the static capacities
    the compiled dispatch shapes provide."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    res = run_policy(policy, cfg, segs, cm, tol)
    q_send = np.asarray(res.plan["q_send_idx"])
    kv_send = np.asarray(res.plan["kv_send_idx"])
    kv_gather = np.asarray(res.plan["kv_gather"])
    assert ((q_send >= 0).sum(-1) <= cfg.cq).all()
    assert ((kv_send >= 0).sum(-1) <= cfg.ckv).all()
    assert ((kv_gather >= 0).sum(-1) <= cfg.nkv).all()
    # ... and the per-pair send lists are dense prefixes (pad = tail):
    # a dead slot is never followed by a live one
    for arr in (q_send, kv_send):
        live = arr >= 0
        assert not (~live[..., :-1] & live[..., 1:]).any()


@property_case
def test_load_accounting_conserves_work(s):
    """Reported loads equal the recomputed per-server cost over speed,
    and total work is conserved: sum(loads * speeds) == total cost."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    res = run_policy(policy, cfg, segs, cm, tol)
    _docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                                cfg.n_servers)
    cost = block_costs(doc_of, bi_of, cfg.blk, cm)
    live = doc_of >= 0
    expect = np.zeros(cfg.n_servers)
    np.add.at(expect, res.assign[live].astype(np.int64), cost[live])
    expect = expect / cfg.speeds()
    np.testing.assert_allclose(res.loads, expect, rtol=1e-9)
    np.testing.assert_allclose((res.loads * cfg.speeds()).sum(),
                               cost[live].sum(), rtol=1e-9)
    assert res.stats["load_max_over_mean"] >= 1.0 - 1e-12 \
        or cost[live].sum() == 0


@property_case
def test_planning_is_deterministic(s):
    """Same inputs -> bit-identical plans and assignments (the replay
    guarantee the prefetch path depends on)."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    a = run_policy(policy, cfg, segs, cm, tol)
    b = run_policy(policy, cfg, segs, cm, tol)
    np.testing.assert_array_equal(a.assign, b.assign)
    for key in a.plan.keys():
        np.testing.assert_array_equal(np.asarray(a.plan[key]),
                                      np.asarray(b.plan[key]),
                                      err_msg=f"{policy}:{key}")


@property_case
def test_balanced_never_worse_than_identity(s):
    """The greedy scheduler only moves work toward deficit servers: its
    max modeled time never exceeds identity's."""
    cfg, segs, cm, tol = gen_scenario(s)
    ident = run_policy("identity", cfg, segs, cm, tol)
    bal = run_policy("balanced", cfg, segs, cm, tol)
    assert bal.loads.max() <= ident.loads.max() * (1 + 1e-9), \
        (bal.loads, ident.loads)


@property_case
def test_infeasible_raises_capacity_error(s):
    """Assignments that cannot fit the static shapes raise
    PlanCapacityError with diagnostics — never a bare assert and never
    a silently-truncated plan."""
    cfg, segs, _cm, _tol = gen_scenario(s)
    if cfg.n_servers == 1:
        return                              # nothing can overflow
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                               cfg.n_servers)
    if not (doc_of >= 0).any():
        return
    tiny = CADConfig(n_servers=cfg.n_servers, blk=cfg.blk, nb=cfg.nb,
                     cq=s.int_(1, 2), ckv=s.int_(1, 2),
                     nkv=s.int_(1, cfg.nb + 1))
    # stress assignment: everything on server 0
    assign = np.zeros_like(identity_assignment(tiny))
    try:
        plan = plan_from_assignment(tiny, assign, doc_of, bi_of, docs)
    except PlanCapacityError as e:
        assert e.capacity in ("CQ", "CKV", "NKV")
        assert e.needed > e.available >= 0
        assert str(e.capacity) in str(e)
        return
    # a successful build must actually be feasible: re-verify coverage
    served, dupes = plan_served_blocks(tiny, plan)
    assert not dupes
    assert all(doc_of[g] >= 0 for g in served)
    assert sum(1 for g in range(len(doc_of)) if doc_of[g] >= 0) \
        == len(served)


@property_case
def test_membership_subset_invariant(s):
    """Elastic membership (DESIGN.md §9): with a random non-empty
    proper subset of servers excluded (drained/dead pool members),
    every policy still serves each live block exactly once, never on an
    excluded server, leaves excluded loads at zero, and replans
    bit-identically — the invariant the recovery/epoch machinery
    depends on.  Builds that genuinely cannot fit the survivors' caps
    must fail with PlanCapacityError, never silently truncate."""
    cfg, segs, cm, tol = gen_scenario(s)
    if cfg.n_servers == 1:
        return                               # no proper subset exists
    d = cfg.n_servers
    mask = s.int_(1, 2 ** d - 2)             # >=1 excluded, >=1 survivor
    exclude = tuple(i for i in range(d) if mask >> i & 1)
    policy = s.choice(POLICIES)
    try:
        res = run_policy_excl(policy, cfg, segs, cm, tol, exclude)
    except PlanCapacityError as e:
        assert e.capacity in ("CQ", "CKV", "NKV")
        return
    _docs, doc_of, _bi = layout_from_segments(segs, cfg.blk, d)
    served, dupes = plan_served_blocks(cfg, res.plan)
    assert not dupes, f"{policy}: blocks served twice: {dupes}"
    for g in range(d * cfg.nb):
        if doc_of[g] >= 0:
            assert g in served, f"{policy}: live block {g} dropped " \
                f"under exclude={exclude}"
            assert served[g] not in exclude, \
                f"{policy}: block {g} served on excluded " \
                f"{served[g]} (exclude={exclude})"
        else:
            assert g not in served
    for e in exclude:
        assert res.loads[e] == 0.0, (policy, exclude, res.loads)
    again = run_policy_excl(policy, cfg, segs, cm, tol, exclude)
    np.testing.assert_array_equal(res.assign, again.assign)
    for key in res.plan.keys():
        np.testing.assert_array_equal(np.asarray(res.plan[key]),
                                      np.asarray(again.plan[key]),
                                      err_msg=f"{policy}:{key}")


def run_policy_excl(policy, cfg, segs, cost_model, tolerance, exclude):
    return get_planner(policy)(cfg, segs, comm=None, tolerance=tolerance,
                               cost_model=cost_model, exclude=exclude)


@property_case
def test_memory_budget_invariant(s):
    """HBM budgets (DESIGN.md §11): every successful plan's resident
    bytes fit the budget on every server (streamed docs clamped to the
    chunk), the reported residency matches an independent recompute,
    coverage still holds, and infeasible builds raise PlanMemoryError
    with over-budget diagnostics — never a silent overflow."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(POLICIES)
    mem = MemoryModel(CommModel(2, 8, 2))
    base = get_planner(policy)(cfg, segs, comm=None, tolerance=tol,
                               cost_model=cm, mem_model=mem)
    resident0 = np.asarray(base.resident_bytes, np.float64)
    if resident0.max() <= 0:
        return                               # all-padding batch
    factor = s.choice([1.0, 0.8, 0.6])
    budgets = np.full(cfg.n_servers, factor * resident0.max())
    chunk = s.choice([0, 1, 2])
    try:
        res = get_planner(policy)(cfg, segs, comm=None, tolerance=tol,
                                  cost_model=cm, mem_model=mem,
                                  budgets=budgets, stream_chunk=chunk)
    except PlanMemoryError as e:
        assert e.resident_bytes > e.budget_bytes >= 0
        assert "resident bytes" in str(e)
        return
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                               cfg.n_servers)
    rec = assignment_resident_bytes(res.assign, doc_of, bi_of, cfg.blk,
                                    cfg.n_servers, mem,
                                    streamed=res.streamed,
                                    stream_chunk=chunk)
    np.testing.assert_allclose(np.asarray(res.resident_bytes), rec,
                               rtol=1e-9)
    assert (np.asarray(res.resident_bytes) <= budgets + 1e-9).all(), \
        (policy, res.resident_bytes, budgets)
    served, dupes = plan_served_blocks(cfg, res.plan)
    assert not dupes
    assert len(served) == int((doc_of >= 0).sum())


@property_case
def test_stats_moves_match_assignment(s):
    """n_moves counts exactly the blocks served away from home."""
    cfg, segs, cm, tol = gen_scenario(s)
    policy = s.choice(["per_doc_cp", "balanced"])
    res = run_policy(policy, cfg, segs, cm, tol)
    _docs, doc_of, _bi = layout_from_segments(segs, cfg.blk,
                                              cfg.n_servers)
    home = identity_assignment(cfg)
    if policy == "per_doc_cp":
        # per_doc_cp counts every re-homed block, live or not
        assert res.stats["n_moves"] == int((res.assign != home).sum())
    else:
        live = doc_of >= 0
        moved = int((res.assign[live] != home[live]).sum())
        # net displacement requires at least one greedy range-move
        if moved > 0:
            assert res.stats["n_moves"] > 0
        if res.stats["n_moves"] == 0:
            assert moved == 0
    assert res.stats["comm_bytes"] >= 0.0


# --------------------------------------------- mask-structured tasks (§12)
def gen_mask(s):
    """Random non-trivial task-shape spec scaled to BLK (DESIGN.md §12)."""
    if s.choice(["sliding", "dilated"]) == "sliding":
        return MaskSpec(kind="sliding",
                        window=s.choice([BLK // 2, BLK, 3 * BLK]),
                        sink=s.choice([0, BLK]))
    return MaskSpec(kind="dilated", rate=s.choice([2, 3, 4]))


def run_policy_mask(policy, cfg, segs, cost_model, tolerance, mask):
    return get_planner(policy)(cfg, segs, comm=None, tolerance=tolerance,
                               cost_model=cost_model, mask=mask)


@property_case
def test_masked_coverage_loads_and_capacities(s):
    """Mask-structured splits keep every plan invariant: exactly-once
    coverage, dense send prefixes within static capacities, loads equal
    to the live-block cost recompute (work conserved under speeds), and
    bit-identical replanning."""
    cfg, segs, cm, tol = gen_scenario(s)
    mask = gen_mask(s)
    policy = s.choice(POLICIES)
    res = run_policy_mask(policy, cfg, segs, cm, tol, mask)
    _docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                                cfg.n_servers)
    served, dupes = plan_served_blocks(cfg, res.plan)
    assert not dupes, f"{policy}/{mask.describe()}: served twice: {dupes}"
    for g in range(cfg.n_servers * cfg.nb):
        if doc_of[g] >= 0:
            assert g in served and served[g] == int(res.assign[g]), \
                f"{policy}/{mask.describe()}: block {g} miscovered"
        else:
            assert g not in served
    for key in ("q_send_idx", "kv_send_idx"):
        arr = np.asarray(res.plan[key])
        cap = cfg.cq if key == "q_send_idx" else cfg.ckv
        assert ((arr >= 0).sum(-1) <= cap).all()
        live = arr >= 0
        assert not (~live[..., :-1] & live[..., 1:]).any()
    cost = block_costs(doc_of, bi_of, cfg.blk, cm, mask)
    live = doc_of >= 0
    expect = np.zeros(cfg.n_servers)
    np.add.at(expect, res.assign[live].astype(np.int64), cost[live])
    np.testing.assert_allclose(res.loads, expect / cfg.speeds(),
                               rtol=1e-9)
    np.testing.assert_allclose((res.loads * cfg.speeds()).sum(),
                               cost[live].sum(), rtol=1e-9)
    again = run_policy_mask(policy, cfg, segs, cm, tol, mask)
    np.testing.assert_array_equal(res.assign, again.assign)


@property_case
def test_masked_balanced_and_memory_budget(s):
    """Under live-block pricing the greedy scheduler still never leaves
    a higher max modeled time than identity, and HBM budgets keep their
    inclusive-fit guarantee (residency is the full kv prefix the gather
    buffer realizes, mask or not — DESIGN.md §11/§12)."""
    cfg, segs, cm, tol = gen_scenario(s)
    mask = gen_mask(s)
    ident = run_policy_mask("identity", cfg, segs, cm, tol, mask)
    bal = run_policy_mask("balanced", cfg, segs, cm, tol, mask)
    assert bal.loads.max() <= ident.loads.max() * (1 + 1e-9), \
        (mask.describe(), bal.loads, ident.loads)
    mem = MemoryModel(CommModel(2, 8, 2))
    policy = s.choice(POLICIES)
    base = get_planner(policy)(cfg, segs, comm=None, tolerance=tol,
                               cost_model=cm, mem_model=mem, mask=mask)
    resident0 = np.asarray(base.resident_bytes, np.float64)
    if resident0.max() <= 0:
        return                               # all-padding batch
    budgets = np.full(cfg.n_servers, s.choice([1.0, 0.7]) *
                      resident0.max())
    try:
        res = get_planner(policy)(cfg, segs, comm=None, tolerance=tol,
                                  cost_model=cm, mem_model=mem,
                                  budgets=budgets, mask=mask)
    except PlanMemoryError as e:
        assert e.resident_bytes > e.budget_bytes >= 0
        return
    docs, doc_of, bi_of = layout_from_segments(segs, cfg.blk,
                                               cfg.n_servers)
    rec = assignment_resident_bytes(res.assign, doc_of, bi_of, cfg.blk,
                                    cfg.n_servers, mem,
                                    streamed=res.streamed)
    np.testing.assert_allclose(np.asarray(res.resident_bytes), rec,
                               rtol=1e-9)
    assert (np.asarray(res.resident_bytes) <= budgets + 1e-9).all()
    served, dupes = plan_served_blocks(cfg, res.plan)
    assert not dupes
    assert len(served) == int((doc_of >= 0).sum())
