"""SSD intra-chunk Pallas kernel: sweeps + equivalence with the model's
chunked-scan reference path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; property tests only
from hypothesis import given, settings, strategies as st

from repro.kernels.ssd import kernel as K
from repro.kernels.ssd import ref as R


def make(key, Bt, Kc, c, H, N, P, resets=True, seed=0):
    ks = jax.random.split(key, 5)
    C_ = jax.random.normal(ks[0], (Bt, Kc, c, H, N))
    B_ = jax.random.normal(ks[1], (Bt, Kc, c, H, N))
    x = jax.random.normal(ks[2], (Bt, Kc, c, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (Bt, Kc, c, H)))
    la = -jax.nn.softplus(jax.random.normal(ks[4], (Bt, Kc, c, H)))
    csum = jnp.cumsum(la, axis=2)
    rng = np.random.default_rng(seed)
    if resets:
        nr = np.sort(rng.integers(0, 3, (Bt, Kc, c)), axis=-1)
    else:
        nr = np.zeros((Bt, Kc, c), np.int64)
    return C_, B_, x, dt, csum, jnp.asarray(nr, jnp.int32)


def check(args, atol=1e-4):
    C_, B_, x, dt, csum, nr = args
    y, stt = K.ssd_chunk(*args)
    Bt, Kc, c, H, _ = C_.shape
    for b in range(Bt):
        for k in range(Kc):
            for h in range(H):
                ey, es = R.ref_ssd_chunk(C_[b, k, :, h], B_[b, k, :, h],
                                         x[b, k, :, h], dt[b, k, :, h],
                                         csum[b, k, :, h], nr[b, k])
                np.testing.assert_allclose(np.asarray(y[b, k, :, h]),
                                           np.asarray(ey), atol=atol)
                np.testing.assert_allclose(np.asarray(stt[b, k, h]),
                                           np.asarray(es), atol=atol)


@pytest.mark.parametrize("Bt,Kc,c,H,N,P", [
    (2, 3, 128, 2, 64, 32),
    (1, 2, 256, 1, 128, 64),
    (2, 2, 64, 4, 32, 64),
])
def test_ssd_chunk_sweep(Bt, Kc, c, H, N, P):
    check(make(jax.random.PRNGKey(0), Bt, Kc, c, H, N, P))


@settings(max_examples=8, deadline=None)
@given(c=st.sampled_from([64, 128]), h=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_ssd_chunk_property(c, h, seed):
    check(make(jax.random.PRNGKey(seed), 1, 2, c, h, 32, 32, seed=seed))


def test_matches_model_chunked_scan():
    """Kernel intra-chunk outputs equal the model's pure-jnp
    `_ssd_chunked` path restricted to one chunk (full equivalence of the
    quadratic part)."""
    from repro.models.layers import _ssd_chunked
    key = jax.random.PRNGKey(7)
    B, S, H, P, G, N = 1, 128, 2, 32, 1, 32   # one chunk
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    log_a = -jax.nn.softplus(jax.random.normal(ks[2], (B, S, H)))
    B_ = jax.random.normal(ks[3], (B, S, G, N))
    C_ = jax.random.normal(ks[4], (B, S, G, N))
    first = jnp.zeros((B, S), bool).at[:, 0].set(True).at[:, 50].set(True)
    y_model = _ssd_chunked(x, dt, log_a, B_, C_, S, first)

    la = jnp.where(first[..., None], 0.0, log_a)
    csum = jnp.cumsum(la, axis=1)
    nr = jnp.cumsum(first.astype(jnp.int32), axis=1)
    rep = H // G
    Cr = jnp.repeat(C_, rep, axis=2)
    Br = jnp.repeat(B_, rep, axis=2)
    y_k, _ = K.ssd_chunk(Cr[:, None], Br[:, None], x[:, None],
                         dt[:, None], csum[:, None], nr[:, None])
    np.testing.assert_allclose(np.asarray(y_k[:, 0]), np.asarray(y_model),
                               atol=2e-4)
