"""CADSession API tests: policy-registry parity with the legacy dict-plan
path (bit-identical plans and global-sim outputs), ping-pong as a typed
PingPongPlan, PlanCapacityError diagnostics, and the async plan
prefetcher (ordering, queue bounds, shutdown, overlap)."""
import dataclasses
import itertools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cad import (CADConfig, CADSession, PingPongPlan,
                       PlanCapacityError, PlanPrefetcher, StepPlan,
                       available_policies, get_planner)
from repro.core import (CADContext, CommModel, cad_attention,
                        identity_plan, per_document_cp_plan,
                        plan_from_schedule, ref_attention, schedule)
from repro.core.dispatch import _global_sim
from repro.parallel import ParallelContext

BLK = 64


def random_layout(rng, d, s, blk=BLK, max_doc_blocks=4):
    segs = np.zeros((d, s), np.int32)
    poss = np.zeros((d, s), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < s:
            nbl = int(rng.integers(1, max_doc_blocks + 1))
            dl = min(nbl * blk, s - t)
            real = dl if rng.random() < 0.7 else max(1, dl - int(
                rng.integers(0, blk)))
            segs[r, t:t + real] = sid
            poss[r, t:t + real] = np.arange(real)
            sid += 1
            t += dl
    return segs, poss


def make_cfg(d, s, blk=BLK):
    nb = s // blk
    return CADConfig(n_servers=d, blk=blk, nb=nb, cq=nb, ckv=2 * nb,
                     nkv=4 * nb)


def legacy_dict_plan(policy, cfg, segs, comm, tolerance):
    """The pre-CADSession way of building each policy's plan, as a raw
    dict (the legacy plan format the dispatch still accepts)."""
    if policy == "identity":
        return identity_plan(cfg, segs).to_dict()
    if policy == "per_doc_cp":
        return per_document_cp_plan(cfg, segs).to_dict()
    sch = schedule(segs, blk=cfg.blk, n_servers=cfg.n_servers, comm=comm,
                   caps=cfg.caps(), tolerance=tolerance)
    return plan_from_schedule(cfg, sch).to_dict()


def test_all_policies_registered():
    assert set(available_policies()) >= {"identity", "per_doc_cp",
                                         "balanced"}


@pytest.mark.parametrize("policy", ["identity", "per_doc_cp", "balanced"])
def test_session_plan_parity_with_legacy(policy):
    """CADSession plans are bit-identical to the legacy path's, and the
    global-sim dispatch output is bit-identical too."""
    rng = np.random.default_rng(7)
    d, s, hq, hkv, dh = 2, 8 * BLK, 4, 2, 32
    segs, poss = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    comm = CommModel(hq, dh, hkv)
    session = CADSession(cfg=cfg, kernel="xla", plan_policy=policy,
                         tolerance=0.05, comm=comm, jmax=cfg.nkv)

    plan, stats = session.plan(segs)
    assert isinstance(plan, StepPlan)
    legacy = legacy_dict_plan(policy, cfg, segs, comm, 0.05)
    for k, v in legacy.items():
        np.testing.assert_array_equal(np.asarray(plan[k]), v, err_msg=k)

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (d, s, hq, dh))
    k_ = jax.random.normal(ks[1], (d, s, hkv, dh))
    v_ = jax.random.normal(ks[2], (d, s, hkv, dh))
    posm = jnp.where(jnp.asarray(segs) > 0, jnp.asarray(poss), -1)

    cad_new = CADContext(cfg=cfg, kernel="xla", jmax=cfg.nkv)
    out_new = _global_sim(q, k_, v_, posm,
                          jax.tree.map(jnp.asarray, plan), cad_new, 0.0,
                          None)
    out_old = _global_sim(q, k_, v_, posm,
                          jax.tree.map(jnp.asarray, legacy), cad_new, 0.0,
                          None)
    np.testing.assert_array_equal(np.asarray(out_new), np.asarray(out_old))
    # and both match monolithic attention
    expected = ref_attention(q, k_, v_, jnp.asarray(segs),
                             jnp.asarray(poss), jnp.asarray(segs),
                             jnp.asarray(poss))
    np.testing.assert_allclose(np.asarray(out_new), np.asarray(expected),
                               atol=2e-5)


def test_session_pingpong_plan_parity():
    """Ping-pong sessions emit a typed PingPongPlan whose halves equal
    the legacy per-nano tuple plans; dispatch matches monolithic CA."""
    rng = np.random.default_rng(11)
    d, rpr, s, hq, hkv, dh = 2, 2, 4 * BLK, 2, 2, 32
    b = d * rpr
    segs_rows = np.zeros((b, s), np.int32)
    poss_rows = np.zeros((b, s), np.int32)
    sid = 1
    for r in range(b):
        t = 0
        while t < s:
            dl = min(int(rng.integers(1, 4)) * BLK, s - t)
            segs_rows[r, t:t + dl] = sid
            poss_rows[r, t:t + dl] = np.arange(dl)
            sid += 1
            t += dl
    nano_tokens = (rpr // 2) * s
    sub = CADConfig(n_servers=d, blk=BLK, nb=nano_tokens // BLK,
                    cq=nano_tokens // BLK, ckv=2 * nano_tokens // BLK,
                    nkv=4 * nano_tokens // BLK)
    comm = CommModel(hq, dh, hkv)
    session = CADSession(cfg=sub, kernel="xla", pingpong=True,
                         tolerance=0.05, plan_policy="balanced",
                         comm=comm, jmax=sub.nkv)
    # rank-major rows: rank r owns rows [r*rpr, (r+1)*rpr)
    segs_rank = segs_rows.reshape(d, rpr * s)
    plan, _ = session.plan(segs_rank)
    assert isinstance(plan, PingPongPlan)
    for i, half in enumerate(plan):
        seg_i = np.stack([segs_rows[r * rpr + i] for r in range(d)])
        sch = schedule(seg_i, blk=BLK, n_servers=d, comm=comm,
                       caps=sub.caps(), tolerance=0.05)
        legacy = plan_from_schedule(sub, sch)
        for key_ in legacy.keys():
            np.testing.assert_array_equal(np.asarray(half[key_]),
                                          np.asarray(legacy[key_]))

    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k_ = jax.random.normal(ks[1], (b, s, hkv, dh))
    v_ = jax.random.normal(ks[2], (b, s, hkv, dh))
    seg, pos = jnp.asarray(segs_rows), jnp.asarray(poss_rows)
    ctx = session.context()
    ctx = ctx.cad.bind_plan(ctx, jax.tree.map(jnp.asarray, plan))
    out = cad_attention(q, k_, v_, seg, pos, seg, pos, ctx=ctx)
    expected = ref_attention(q, k_, v_, seg, pos, seg, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


def test_legacy_fullsize_pingpong_cfg_resized():
    """A CADConfig sized for the full step with pingpong=True is re-sized
    to the nano-batch (the old pipeline behavior), not rejected."""
    d, s = 2, 8 * BLK
    cfg = make_cfg(d, s)                   # full-step geometry
    session = CADSession(cfg=cfg, pingpong=True, tolerance=0.05,
                         comm=CommModel(4, 32, 2))
    segs, _ = random_layout(np.random.default_rng(2), d, s)
    plan, _ = session.plan(segs)
    assert isinstance(plan, PingPongPlan)
    assert np.asarray(plan.ping.q_home_idx).shape == (d, (s // 2) // BLK)


def test_plan_capacity_error_reports_details():
    """CQ overflow raises a diagnostic error, not a bare assert."""
    rng = np.random.default_rng(0)
    d, s = 2, 8 * BLK
    segs = np.zeros((d, s), np.int32)
    # one long doc on rank 0 so head-tail CP must send many q blocks
    segs[0, :] = 1
    segs[1, : 2 * BLK] = 2
    nb = s // BLK
    tiny = CADConfig(n_servers=d, blk=BLK, nb=nb, cq=1, ckv=2 * nb,
                     nkv=4 * nb)
    with pytest.raises(PlanCapacityError) as ei:
        get_planner("per_doc_cp")(tiny, segs)
    e = ei.value
    assert e.capacity == "CQ"
    assert (e.src, e.dst) == (0, 1)
    assert e.needed > e.available == 1
    assert "CQ" in str(e) and "src=0" in str(e)


def test_for_pipeline_does_not_mutate_pipe_cfg():
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(seq_len=256, max_doc_len=256, global_batch=4,
                          n_ranks=2, vocab_size=cfg.vocab_size)
    before = dataclasses.asdict(pipe)
    session = CADSession.for_pipeline(cfg, pipe, plan_policy="balanced")
    assert dataclasses.asdict(pipe) == before
    assert session.cfg.n_servers == 2
    ctx = session.context()
    assert ctx.attn_impl == "cad" and ctx.cad.cfg is session.cfg


# ---------------------------------------------------------- prefetcher
def test_prefetcher_order_and_shutdown():
    items = list(range(20))
    pf = PlanPrefetcher(iter(items), lambda x: x * x, depth=3)
    out = list(pf)
    assert out == [x * x for x in items]
    assert not pf._thread.is_alive()
    pf.close()                               # idempotent


def test_prefetcher_bounded_lookahead():
    pulled = []

    def source():
        for i in itertools.count():
            pulled.append(i)
            yield i

    depth = 2
    pf = PlanPrefetcher(source(), lambda x: x, depth=depth)
    try:
        taken = []
        for _ in range(5):
            taken.append(next(pf))
            time.sleep(0.05)                 # let the worker run ahead
            # look-ahead never exceeds: consumed + queue depth + 1 in fn
            assert len(pulled) <= len(taken) + depth + 1, \
                (len(pulled), len(taken))
        assert taken == list(range(5))
    finally:
        pf.close()
    assert not pf._thread.is_alive()


def test_prefetcher_propagates_worker_exception():
    def bad(x):
        if x == 3:
            raise ValueError("boom at 3")
        return x

    pf = PlanPrefetcher(iter(range(10)), bad, depth=2)
    got = []
    with pytest.raises(ValueError, match="boom at 3"):
        for x in pf:
            got.append(x)
    assert got == [0, 1, 2]
    assert not pf._thread.is_alive()


def test_prefetch_overlaps_planning_with_compute():
    """The overlap claim: a multi-step loop with async prefetch completes
    no slower than with inline (synchronous) planning — the plan of step
    i+1 is built while step i 'computes'."""
    t_plan, t_step, steps = 0.03, 0.03, 6

    def plan_fn(x):
        time.sleep(t_plan)
        return x

    def run(depth):
        src = iter(range(steps))
        t0 = time.perf_counter()
        if depth == 0:
            for item in src:
                plan_fn(item)
                time.sleep(t_step)
        else:
            with PlanPrefetcher(src, plan_fn, depth=depth) as pf:
                for _ in pf:
                    time.sleep(t_step)
        return time.perf_counter() - t0

    sync_wall = run(0)
    async_wall = run(2)
    assert async_wall <= sync_wall, (async_wall, sync_wall)
    # and most of the planning time is actually hidden
    assert async_wall <= steps * t_step + 3 * t_plan, async_wall


def test_attach_plans_matches_synchronous_planning():
    """attach_plans(prefetch=2) yields the same plans, in order, as the
    synchronous path."""
    rng = np.random.default_rng(5)
    d, s = 2, 8 * BLK
    cfg = make_cfg(d, s)
    session = CADSession(cfg=cfg, plan_policy="balanced", tolerance=0.05,
                         comm=CommModel(4, 32, 2), jmax=cfg.nkv)

    def fake_batches(n):
        r = np.random.default_rng(9)
        for _ in range(n):
            segs, _ = random_layout(r, d, s)
            yield {"segment_ids": segs.reshape(d, s)}

    sync = [b["plan"] for b in
            session.attach_plans(fake_batches(5), prefetch=0)]
    pre = [b["plan"] for b in
           session.attach_plans(fake_batches(5), prefetch=2)]
    assert len(sync) == len(pre) == 5
    for a, b in zip(sync, pre):
        for ka, kb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            np.testing.assert_array_equal(ka, kb)


def test_train_runs_through_session():
    """trainer.train with a CADSession: plans prefetched, loss finite."""
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig
    from repro.train.trainer import TrainConfig, train
    cfg = get_config("smollm-360m").reduced()
    pipe = PipelineConfig(distribution="pretrain", max_doc_len=256,
                          seq_len=256, global_batch=4, n_ranks=2,
                          vocab_size=cfg.vocab_size, seed=3)
    session = CADSession.for_pipeline(cfg, pipe, plan_policy="balanced")
    res = train(cfg, pipe, TrainConfig(steps=2, peak_lr=1e-3, warmup=1,
                                       log_every=1), session=session)
    assert len(res["history"]) == 2
    assert np.isfinite(res["history"][-1]["loss"])
    assert "sched_comm_bytes" in res["history"][-1]
    # no stray prefetch workers left behind
    names = [t.name for t in threading.enumerate()]
    assert "cad-plan-prefetch" not in names
