"""Gradient parity of the hand-written Pallas backward kernels.

Both ``flash_bwd`` and ``ca_server_bwd`` (interpret mode on CPU) must
match ``jax.grad`` through the materialized-mask oracles within
fp32-interpret tolerance, across causal/windowed/softcapped/GQA cases and
ragged ``kv_len`` server task batches — and the blockwise-XLA recompute
fallback selected via ``bwd_impl``/``REPRO_KERNEL_BWD`` must agree too.

(Deliberately hypothesis-free, unlike test_kernels_flash.py, so the bwd
parity gate runs even without the dev extra.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import mask_fn, ref_attention
from repro.kernels.packed_flash import kernel as K
from repro.kernels.packed_flash import ops as O
from repro.kernels.packed_flash import ref as R

ATOL = 3e-4


def make_packed(key, B, S, Hq, Hkv, dh, dtype=jnp.float32, n_docs=3,
                pad_tail=0):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, Hq, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, dh)).astype(dtype)
    rng = np.random.default_rng(int(ks[3][0]))
    seg = np.zeros((B, S), np.int32)
    pos = np.zeros((B, S), np.int32)
    body = S - pad_tail
    for b in range(B):
        cuts = np.sort(rng.choice(np.arange(1, body), size=n_docs - 1,
                                  replace=False))
        bounds = np.concatenate([[0], cuts, [body]])
        for d in range(n_docs):
            lo, hi = bounds[d], bounds[d + 1]
            seg[b, lo:hi] = d + 1
            pos[b, lo:hi] = np.arange(hi - lo)
    return q, k, v, jnp.asarray(seg), jnp.asarray(pos)


def make_server_batch(key, T, blk, Hq, Hkv, dh, N, seed=0, pad_last=True):
    """Ragged CA-task batch: each task a (q-block, kv-prefix-range) pair
    with random start/length; the last task is zero-length padding."""
    ks = jax.random.split(key, 4)
    rng = np.random.default_rng(seed)
    q = jax.random.normal(ks[0], (T, blk, Hq, dh)).astype(jnp.float32)
    kb = jax.random.normal(ks[1], (N, blk, Hkv, dh)).astype(jnp.float32)
    vb = jax.random.normal(ks[2], (N, blk, Hkv, dh)).astype(jnp.float32)
    kv_start = np.zeros(T, np.int32)
    kv_len = np.zeros(T, np.int32)
    q_pos = np.zeros((T, blk), np.int32)
    kv_pos = np.zeros((N, blk), np.int32)
    for n in range(N):
        kv_pos[n] = np.arange(blk)
    for t in range(T):
        ln = int(rng.integers(1, min(N, 6) + 1))
        st = int(rng.integers(0, N - ln + 1))
        kv_start[t], kv_len[t] = st, ln
        q_pos[t] = np.arange((ln - 1) * blk, ln * blk)
        for jj in range(ln):
            kv_pos[st + jj] = np.arange(jj * blk, (jj + 1) * blk)
    if pad_last and T > 1:
        kv_len[-1] = 0
        q_pos[-1] = -1
    return (q, kb, vb, jnp.asarray(kv_start), jnp.asarray(kv_len),
            jnp.asarray(q_pos), jnp.asarray(kv_pos))


def grads(loss, *args):
    return jax.grad(loss, argnums=(0, 1, 2))(*args)


def assert_grads_close(ga, gb, atol=ATOL):
    for a, b in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


# ------------------------------------------------------------ packed flash
@pytest.mark.parametrize("causal,window,softcap,Hq,Hkv", [
    (True, 0, 0.0, 4, 2),     # GQA
    (True, 0, 0.0, 4, 4),     # MHA
    (True, 0, 0.0, 8, 1),     # MQA
    (False, 0, 0.0, 4, 2),    # bidirectional
    (True, 64, 0.0, 4, 2),    # sliding window
    (True, 0, 30.0, 4, 2),    # softcap
    (True, 128, 30.0, 6, 2),  # window + softcap, odd GQA factor
])
def test_flash_bwd_parity(causal, window, softcap, Hq, Hkv):
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(0), 2, 256, Hq,
                                    Hkv, 64)

    def loss_k(q_, k_, v_):
        out = O.packed_flash_attention(q_, k_, v_, seg, pos, seg, pos,
                                       causal, window, softcap)
        return jnp.sum(out ** 2)

    def loss_r(q_, k_, v_):
        out = ref_attention(q_, k_, v_, seg, pos, seg, pos, causal=causal,
                            window=window, softcap=softcap)
        return jnp.sum(out ** 2)

    assert_grads_close(grads(loss_k, q, k, v), grads(loss_r, q, k, v))


def test_flash_bwd_small_blocks():
    """Non-default block sizes exercise the pruning arithmetic."""
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(1), 1, 384, 4, 2,
                                    128)
    out, lse = K.flash_fwd(q, k, v, seg, pos, seg, pos, blk_q=64, blk_k=64,
                           return_lse=True)
    do = jax.random.normal(jax.random.PRNGKey(2), out.shape)
    dq, dk, dv = K.flash_bwd(q, k, v, out, lse, do, seg, pos, seg, pos,
                             blk_q=64, blk_k=64)
    f = lambda q_, k_, v_: ref_attention(q_, k_, v_, seg, pos, seg, pos)
    _, vjp = jax.vjp(f, q, k, v)
    assert_grads_close((dq, dk, dv), vjp(do))


def test_flash_bwd_padded_rows_get_zero_grad():
    """Padding tokens (segment 0) are dead rows: lse = LSE_DEAD in the
    residual and no gradient may flow through them."""
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(3), 1, 256, 4, 2,
                                    64, pad_tail=64)

    def loss_k(q_, k_, v_):
        out = O.packed_flash_attention(q_, k_, v_, seg, pos, seg, pos)
        return jnp.sum(out ** 2)

    dq, dk, dv = grads(loss_k, q, k, v)
    dead = np.asarray(seg)[0] == 0
    assert dead.any()
    np.testing.assert_array_equal(np.asarray(dq)[0, dead], 0.0)
    np.testing.assert_array_equal(np.asarray(dk)[0, dead], 0.0)
    np.testing.assert_array_equal(np.asarray(dv)[0, dead], 0.0)


def test_flash_lse_residual_matches_oracle():
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(4), 1, 256, 2, 2,
                                    64, pad_tail=32)
    _, lse = K.flash_fwd(q, k, v, seg, pos, seg, pos, return_lse=True)
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    m = mask_fn(seg, pos, seg, pos, causal=True, window=0)[:, None]
    ref_lse = np.broadcast_to(
        np.asarray(jax.nn.logsumexp(jnp.where(m, logits, -jnp.inf),
                                    axis=-1)), lse.shape)
    live = np.broadcast_to(np.asarray(m.any(-1)), lse.shape)
    np.testing.assert_allclose(np.asarray(lse)[live], ref_lse[live],
                               atol=1e-5)
    assert (np.asarray(lse)[~live] == K.LSE_DEAD).all()


def test_flash_bwd_xla_fallback_parity(monkeypatch):
    """bwd_impl="xla" (and $REPRO_KERNEL_BWD) select the blockwise
    recompute backward; both routes must match the Pallas backward."""
    q, k, v, seg, pos = make_packed(jax.random.PRNGKey(5), 1, 256, 4, 2,
                                    64)

    def loss(impl):
        def f(q_, k_, v_):
            out = O.packed_flash_attention(q_, k_, v_, seg, pos, seg, pos,
                                           True, 0, 50.0, None, impl)
            return jnp.sum(out ** 2)
        return f

    g_pallas = grads(loss("pallas"), q, k, v)
    g_xla = grads(loss("xla"), q, k, v)
    assert_grads_close(g_pallas, g_xla)

    monkeypatch.setenv("REPRO_KERNEL_BWD", "xla")
    g_env = grads(loss(None), q, k, v)
    assert_grads_close(g_env, g_xla)

    monkeypatch.setenv("REPRO_KERNEL_BWD", "bogus")
    with pytest.raises(ValueError, match="bwd impl"):
        grads(loss(None), q, k, v)


# -------------------------------------------------------------- CA server
@pytest.mark.parametrize("causal,window,softcap,Hq,Hkv", [
    (True, 0, 0.0, 4, 2),
    (True, 0, 0.0, 2, 2),
    (True, 0, 0.0, 8, 1),
    (True, 96, 0.0, 4, 2),
    (True, 0, 25.0, 4, 2),
])
def test_ca_server_bwd_parity(causal, window, softcap, Hq, Hkv):
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(6), 5, 64, Hq, Hkv, 64, 7)

    def loss_k(q_, k_, v_):
        out = O.ca_server_attention(q_, k_, v_, st, ln, qp, kp, causal,
                                    window, softcap)
        return jnp.sum(out ** 2)

    def loss_r(q_, k_, v_):
        out = R.ref_ca_server_attention(q_, k_, v_, st, ln, qp, kp,
                                        causal=causal, window=window,
                                        softcap=softcap)
        return jnp.sum(out ** 2)

    assert_grads_close(grads(loss_k, q, kb, vb), grads(loss_r, q, kb, vb))


def test_ca_server_bwd_ragged_and_padded_tasks():
    """Ragged kv_len, overlapping prefix ranges, and a zero-length
    padding task: the padding task's dq must be exactly zero and kv
    blocks outside every range get zero dk/dv."""
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(7), 6, 64, 4, 2, 64, 8)

    def loss_k(q_, k_, v_):
        return jnp.sum(O.ca_server_attention(q_, k_, v_, st, ln, qp,
                                             kp) ** 2)

    def loss_r(q_, k_, v_):
        return jnp.sum(R.ref_ca_server_attention(q_, k_, v_, st, ln, qp,
                                                 kp) ** 2)

    gk = grads(loss_k, q, kb, vb)
    assert_grads_close(gk, grads(loss_r, q, kb, vb))
    assert int(ln[-1]) == 0
    np.testing.assert_array_equal(np.asarray(gk[0])[-1], 0.0)
    starts, lens = np.asarray(st), np.asarray(ln)
    covered = np.zeros(kb.shape[0], bool)
    for s, n in zip(starts, lens):
        covered[s:s + n] = True
    if not covered.all():
        np.testing.assert_array_equal(np.asarray(gk[1])[~covered], 0.0)
        np.testing.assert_array_equal(np.asarray(gk[2])[~covered], 0.0)


def test_ca_server_bwd_respects_jmax():
    """jmax (the scheduler's kv-blocks-per-task bound) limits the dq
    walk exactly like the forward — results identical to jmax=N."""
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(8), 4, 64, 4, 2, 64, 8, seed=3)
    jmax = int(np.asarray(ln).max())

    def loss(jm):
        def f(q_, k_, v_):
            out = O.ca_server_attention(q_, k_, v_, st, ln, qp, kp, True,
                                        0, 0.0, None, jm)
            return jnp.sum(out ** 2)
        return f

    assert_grads_close(grads(loss(jmax), q, kb, vb),
                       grads(loss(0), q, kb, vb), atol=1e-6)


def test_ca_server_bwd_xla_fallback_parity():
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(9), 4, 64, 4, 2, 64, 6)

    def loss(impl):
        def f(q_, k_, v_):
            out = O.ca_server_attention(q_, k_, v_, st, ln, qp, kp, True,
                                        0, 0.0, None, 0, impl)
            return jnp.sum(out ** 2)
        return f

    assert_grads_close(grads(loss("pallas"), q, kb, vb),
                       grads(loss("xla"), q, kb, vb))


def test_ca_server_lse_residual_matches_oracle():
    q, kb, vb, st, ln, qp, kp = make_server_batch(
        jax.random.PRNGKey(10), 4, 64, 4, 2, 64, 6, seed=1)
    _, lse = K.ca_server_fwd(q, kb, vb, st, ln, qp, kp, return_lse=True)
    T, blk, hq, dh = q.shape
    N = kb.shape[0]
    scale = dh ** -0.5
    kf = jnp.repeat(kb.reshape(N * blk, -1, dh), hq // kb.shape[2], axis=1)
    logits = jnp.einsum("tqhd,khd->thqk", q, kf) * scale
    blk_idx = jnp.arange(N)
    in_rng = (blk_idx[None, :] >= st[:, None]) & \
             (blk_idx[None, :] < st[:, None] + ln[:, None])
    m = jnp.repeat(in_rng, blk, axis=1)[:, None, None, :]
    m = m & (kp.reshape(-1) >= 0)[None, None, None, :]
    m = m & (qp >= 0)[:, None, :, None]
    m = m & (qp[:, None, :, None] >= kp.reshape(-1)[None, None, None, :])
    ref_lse = np.broadcast_to(
        np.asarray(jax.nn.logsumexp(jnp.where(m, logits, -jnp.inf),
                                    axis=-1)), lse.shape)
    live = np.broadcast_to(np.asarray(m.any(-1)), lse.shape)
    np.testing.assert_allclose(np.asarray(lse)[live], ref_lse[live],
                               atol=1e-5)
    assert (np.asarray(lse)[~live] == K.LSE_DEAD).all()
