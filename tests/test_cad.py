"""CAD core tests: scheduler invariants (hypothesis), plan properties,
dispatch equivalence (CAD == monolithic attention), gradients, ping-pong,
and the real shard_map path (subprocess with fake devices)."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev extra; property tests only
from hypothesis import given, settings, strategies as st

from repro.core import (CADConfig, CADContext, CommModel, cad_attention,
                        identity_plan, imbalance, per_document_cp_plan,
                        plan_from_schedule, ref_attention, schedule)
from repro.core.dispatch import _global_sim
from repro.parallel import ParallelContext, ShardingRules

BLK = 64


def random_layout(rng, d, s, blk=BLK, max_doc_blocks=4):
    segs = np.zeros((d, s), np.int32)
    poss = np.zeros((d, s), np.int32)
    sid = 1
    for r in range(d):
        t = 0
        while t < s:
            nbl = int(rng.integers(1, max_doc_blocks + 1))
            dl = min(nbl * blk, s - t)
            # occasionally leave padding (short doc not filling its blocks)
            real = dl if rng.random() < 0.7 else max(1, dl - int(
                rng.integers(0, blk)))
            segs[r, t:t + real] = sid
            poss[r, t:t + real] = np.arange(real)
            sid += 1
            t += dl
    return segs, poss


def make_cfg(d, s, blk=BLK):
    nb = s // blk
    return CADConfig(n_servers=d, blk=blk, nb=nb, cq=nb, ckv=2 * nb,
                     nkv=4 * nb)


def plan_coverage(plan, cfg, segs):
    """Every real q-block appears exactly once (home or exactly one send)."""
    d, nb = cfg.n_servers, cfg.nb
    seen = np.zeros((d, nb), np.int64)
    for r in range(d):
        for i in plan["q_home_idx"][r]:
            if i >= 0:
                seen[r, i] += 1
        for s_ in range(d):
            for i in plan["q_send_idx"][r, s_]:
                if i >= 0:
                    seen[r, i] += 1
    lead = segs.reshape(d, nb, cfg.blk)[:, :, 0]
    real = lead > 0
    assert (seen[real] == 1).all(), "real block not covered exactly once"
    assert (seen[~real] == 0).all(), "padding block dispatched"


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([2, 4]), nbr=st.integers(4, 10),
       tol=st.sampled_from([0.02, 0.1, 0.3]), seed=st.integers(0, 10 ** 6))
def test_scheduler_properties(d, nbr, tol, seed):
    rng = np.random.default_rng(seed)
    s = nbr * BLK
    segs, _ = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    comm = CommModel(4, 32, 2)
    sch = schedule(segs, blk=BLK, n_servers=d, comm=comm, caps=cfg.caps(),
                   tolerance=tol)
    # conservation: assignment is a permutation-free total map
    assert sch.assign.shape == (d * cfg.nb,)
    assert ((sch.assign >= 0) & (sch.assign < d)).all()
    # loads consistent with assignment
    cost = np.where(sch.doc_of_block >= 0,
                    (sch.bi_of_block + 1) * float(BLK * BLK), 0.0)
    loads = np.array([cost[sch.assign == s_].sum() for s_ in range(d)])
    np.testing.assert_allclose(loads, sch.loads, rtol=1e-9)
    # scheduler never worsens the straggler
    home = (np.arange(d * cfg.nb) // cfg.nb)
    loads0 = np.array([cost[home == s_].sum() for s_ in range(d)])
    assert imbalance(sch.loads) <= imbalance(loads0) + 1e-9
    # plan builds without violating capacities, covers every block
    plan = plan_from_schedule(cfg, sch)
    plan_coverage(plan, cfg, segs)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10 ** 6), tol=st.sampled_from([0.05, 0.2]))
def test_dispatch_equivalence_property(seed, tol):
    """CAD(scheduled plan) == monolithic attention, for random layouts."""
    rng = np.random.default_rng(seed)
    d, s, hq, hkv, dh = 4, 8 * BLK, 4, 2, 32
    segs, poss = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    comm = CommModel(hq, dh, hkv)
    sch = schedule(segs, blk=BLK, n_servers=d, comm=comm, caps=cfg.caps(),
                   tolerance=tol)
    plan = jax.tree.map(jnp.asarray, plan_from_schedule(cfg, sch))
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (d, s, hq, dh))
    k = jax.random.normal(ks[1], (d, s, hkv, dh))
    v = jax.random.normal(ks[2], (d, s, hkv, dh))
    seg = jnp.asarray(segs)
    pos = jnp.asarray(poss)
    expected = ref_attention(q, k, v, seg, pos, seg, pos)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv)
    posm = jnp.where(seg > 0, pos, -1)
    out = _global_sim(q, k, v, posm, plan, cad, 0.0, None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


@pytest.mark.parametrize("plan_fn", [identity_plan, per_document_cp_plan])
def test_dispatch_equivalence_fixed_plans(plan_fn):
    rng = np.random.default_rng(3)
    d, s, hq, hkv, dh = 4, 8 * BLK, 4, 2, 32
    segs, poss = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    plan = jax.tree.map(jnp.asarray, plan_fn(cfg, segs))
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (d, s, hq, dh))
    k = jax.random.normal(ks[1], (d, s, hkv, dh))
    v = jax.random.normal(ks[2], (d, s, hkv, dh))
    seg, pos = jnp.asarray(segs), jnp.asarray(poss)
    expected = ref_attention(q, k, v, seg, pos, seg, pos)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
    out = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


def test_dispatch_pallas_server():
    rng = np.random.default_rng(5)
    d, s, hq, hkv, dh = 2, 6 * BLK, 2, 1, 64
    segs, poss = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    comm = CommModel(hq, dh, hkv)
    sch = schedule(segs, blk=BLK, n_servers=d, comm=comm, caps=cfg.caps(),
                   tolerance=0.05)
    plan = jax.tree.map(jnp.asarray, plan_from_schedule(cfg, sch))
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (d, s, hq, dh))
    k = jax.random.normal(ks[1], (d, s, hkv, dh))
    v = jax.random.normal(ks[2], (d, s, hkv, dh))
    seg, pos = jnp.asarray(segs), jnp.asarray(poss)
    expected = ref_attention(q, k, v, seg, pos, seg, pos)
    cad = CADContext(cfg=cfg, plan=plan, kernel="pallas", jmax=cfg.nkv)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
    out = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


def test_dispatch_gradients():
    """d loss/d q,k,v through the CAD dispatch equals the monolithic
    gradient — the backward A2A mirror works by construction."""
    rng = np.random.default_rng(7)
    d, s, hq, hkv, dh = 2, 4 * BLK, 2, 2, 32
    segs, poss = random_layout(rng, d, s)
    cfg = make_cfg(d, s)
    comm = CommModel(hq, dh, hkv)
    sch = schedule(segs, blk=BLK, n_servers=d, comm=comm, caps=cfg.caps(),
                   tolerance=0.05)
    plan = jax.tree.map(jnp.asarray, plan_from_schedule(cfg, sch))
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (d, s, hq, dh))
    k = jax.random.normal(ks[1], (d, s, hkv, dh))
    v = jax.random.normal(ks[2], (d, s, hkv, dh))
    seg, pos = jnp.asarray(segs), jnp.asarray(poss)
    cad = CADContext(cfg=cfg, plan=plan, kernel="xla", jmax=cfg.nkv)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)

    def loss_cad(q_, k_, v_):
        return jnp.sum(cad_attention(q_, k_, v_, seg, pos, seg, pos,
                                     ctx=ctx) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(ref_attention(q_, k_, v_, seg, pos, seg, pos) ** 2)

    gc = jax.grad(loss_cad, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gc, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_pingpong_equivalence():
    """Two nano-batches with independent plans == one monolithic pass."""
    rng = np.random.default_rng(11)
    d, rpr, s, hq, hkv, dh = 2, 2, 4 * BLK, 2, 2, 32
    b = d * rpr
    segs_rows = np.zeros((b, s), np.int32)
    poss_rows = np.zeros((b, s), np.int32)
    sid = 1
    for r in range(b):
        t = 0
        while t < s:
            dl = min(int(rng.integers(1, 4)) * BLK, s - t)
            segs_rows[r, t:t + dl] = sid
            poss_rows[r, t:t + dl] = np.arange(dl)
            sid += 1
            t += dl
    # per-nano plans: each nano is one row per rank here (rpr=2, half=1)
    nano_tokens = (rpr // 2) * s
    sub = CADConfig(n_servers=d, blk=BLK, nb=nano_tokens // BLK,
                    cq=nano_tokens // BLK, ckv=2 * nano_tokens // BLK,
                    nkv=4 * nano_tokens // BLK)
    comm = CommModel(hq, dh, hkv)
    plans = []
    for i in range(2):
        # rank-major rows: rank r owns rows [r*rpr, (r+1)*rpr)
        rows = [segs_rows[r * rpr + i] for r in range(d)]
        seg_i = np.stack(rows)
        sch = schedule(seg_i, blk=BLK, n_servers=d, comm=comm,
                       caps=sub.caps(), tolerance=0.05)
        plans.append(jax.tree.map(jnp.asarray,
                                  plan_from_schedule(sub, sch)))
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, hq, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    seg, pos = jnp.asarray(segs_rows), jnp.asarray(poss_rows)
    expected = ref_attention(q, k, v, seg, pos, seg, pos)
    cad = CADContext(cfg=sub, plan=tuple(plans), kernel="xla",
                     jmax=sub.nkv, pingpong=True)
    ctx = ParallelContext(mesh=None, attn_impl="cad", cad=cad)
    out = cad_attention(q, k, v, seg, pos, seg, pos, ctx=ctx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5)


SHARD_MAP_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import sys; sys.path.insert(0, 'src')
import numpy as np, jax, jax.numpy as jnp
from repro.core import (CADConfig, CADContext, CommModel, cad_attention,
                        plan_from_schedule, ref_attention, schedule)
from repro.parallel import ParallelContext, ShardingRules

rng = np.random.default_rng(0)
D, S, blk, Hq, Hkv, dh = 8, 512, 64, 4, 2, 32
nb = S // blk
segs = np.zeros((D, S), np.int32); poss = np.zeros((D, S), np.int32); sid = 1
for r in range(D):
    t = 0
    while t < S:
        dl = min(int(rng.integers(1, 6)) * blk, S - t)
        segs[r, t:t+dl] = sid; poss[r, t:t+dl] = np.arange(dl)
        sid += 1; t += dl
cfg = CADConfig(n_servers=D, blk=blk, nb=nb, cq=nb, ckv=2*nb, nkv=4*nb)
comm = CommModel(Hq, dh, Hkv)
sch = schedule(segs, blk=blk, n_servers=D, comm=comm, caps=cfg.caps(),
               tolerance=0.05)
plan = jax.tree.map(jnp.asarray, plan_from_schedule(cfg, sch))
mesh = jax.make_mesh((8,), ('data',))
rules = ShardingRules(batch=('data',), cad_axis=('data',))
key = jax.random.PRNGKey(0); ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (D, S, Hq, dh))
k = jax.random.normal(ks[1], (D, S, Hkv, dh))
v = jax.random.normal(ks[2], (D, S, Hkv, dh))
seg, pos = jnp.asarray(segs), jnp.asarray(poss)
expected = ref_attention(q, k, v, seg, pos, seg, pos)
cad = CADContext(cfg=cfg, plan=plan, kernel='xla', jmax=nb)
ctx = ParallelContext(mesh=mesh, rules=rules, attn_impl='cad', cad=cad)
out = jax.jit(lambda *a: cad_attention(*a, ctx=ctx))(q, k, v, seg, pos,
                                                     seg, pos)
err = float(jnp.max(jnp.abs(out - expected)))
assert err < 2e-5, err
print('OK', err)
"""


def test_shard_map_dispatch_subprocess():
    """The real distributed path on 8 fake XLA host devices (isolated in a
    subprocess so the main session keeps a single device)."""
    r = subprocess.run([sys.executable, "-c", SHARD_MAP_SCRIPT],
                       capture_output=True, text=True, cwd="/root/repo",
                       timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
